"""Span tracer: contextmanager API, monotonic clocks, thread-safe.

Instruments the phases that were previously invisible between steps —
runtime ticks, jitted steps (compile vs steady-state), remesh checkpoint
round-trips, prefill chunks vs decode batches, COW device copies, host
swap in/out, fleet invite→accept — and exports Chrome-trace JSON
(``{"traceEvents": [...]}``, "X" complete events, µs timestamps) that
loads directly in Perfetto / ``chrome://tracing``.

Nesting is tracked per thread (``threading.local`` stacks); completed
spans land in one lock-protected list with a bounded cap so multi-day
fleet runs cannot exhaust memory (drops are counted, never silent).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import threading
import time
from typing import Dict, List, Optional

from repro.obs.schema import encode_record, versioned


@dataclasses.dataclass
class SpanRecord:
    name: str
    ts_us: float          # start, µs since tracer epoch (monotonic)
    dur_us: float
    tid: int
    depth: int            # nesting depth at start (0 = top level)
    args: Dict = dataclasses.field(default_factory=dict)


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_start", "_depth")

    def __init__(self, tracer: "SpanTracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # tolerate out-of-order exits
            stack.remove(self)
        if exc_type is not None:
            self.args = dict(self.args, error=exc_type.__name__)
        self._tracer._record(self, self._start, end)
        return False  # never swallow exceptions


class SpanTracer:
    def __init__(self, enabled: bool = True, max_spans: int = 200_000):
        self.enabled = enabled
        self.max_spans = max_spans
        self.dropped = 0
        self._epoch = time.perf_counter()
        self._spans: List[SpanRecord] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        # live stacks by thread id, for timeout/hang diagnosis
        self._live: Dict[int, List[_Span]] = {}

    def _stack(self) -> List[_Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
            with self._lock:
                self._live[threading.get_ident()] = stack
        return stack

    def span(self, name: str, **args):
        """``with tracer.span("serve.decode", batch=4): ...``"""
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, args)

    def _record(self, span: _Span, start: float, end: float) -> None:
        rec = SpanRecord(
            name=span.name,
            ts_us=(start - self._epoch) * 1e6,
            dur_us=(end - start) * 1e6,
            tid=threading.get_ident(),
            depth=span._depth,
            args=span.args,
        )
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(rec)

    # ------------------------------------------------------------------
    # introspection / export
    # ------------------------------------------------------------------
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def recent(self, n: int = 20) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans[-n:])

    def active_stack(self) -> Dict[int, List[str]]:
        """Currently-open span names per thread (hang diagnosis)."""
        with self._lock:
            return {tid: [f"{s.name}{s.args or ''}" for s in stack]
                    for tid, stack in self._live.items() if stack}

    def to_records(self) -> List[Dict]:
        return [encode_record(s) for s in self.spans()]

    def chrome_trace(self) -> Dict:
        """Chrome-trace JSON dict (loads in Perfetto / chrome://tracing)."""
        events: List[Dict] = [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "repro"}},
        ]
        tids = {}
        for s in self.spans():
            # renumber thread ids densely so the trace UI rows read 0,1,2…
            tid = tids.setdefault(s.tid, len(tids))
            events.append({
                "name": s.name,
                "ph": "X",
                "ts": s.ts_us,
                "dur": s.dur_us,
                "pid": 1,
                "tid": tid,
                "args": encode_record(s.args),
            })
        for raw, tid in tids.items():
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid, "args": {"name": f"thread-{raw}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": versioned({"dropped_spans": self.dropped})}

    def save_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def by_name(self) -> Dict[str, Dict]:
        """Aggregate spans by name: count / total / mean / max (µs)."""
        agg: Dict[str, Dict] = {}
        for s in self.spans():
            a = agg.setdefault(s.name,
                               {"count": 0, "total_us": 0.0, "max_us": 0.0})
            a["count"] += 1
            a["total_us"] += s.dur_us
            if s.dur_us > a["max_us"]:
                a["max_us"] = s.dur_us
        for a in agg.values():
            a["mean_us"] = a["total_us"] / a["count"]
        return agg

    def debug_dump(self, file=None, last: int = 20) -> None:
        """Human-readable dump of live stacks + recent spans (timeouts)."""
        out = file if file is not None else sys.stderr
        active = self.active_stack()
        if active:
            print("[obs] active span stacks:", file=out)
            for tid, names in active.items():
                print(f"[obs]   thread {tid}: " + " > ".join(names), file=out)
        else:
            print("[obs] no active spans", file=out)
        recent = self.recent(last)
        if recent:
            print(f"[obs] last {len(recent)} completed spans:", file=out)
            for s in recent:
                print(f"[obs]   {s.ts_us / 1e6:10.3f}s "
                      f"{s.dur_us / 1e3:9.3f}ms  {s.name} {s.args or ''}",
                      file=out)
        if self.dropped:
            print(f"[obs] ({self.dropped} spans dropped at cap "
                  f"{self.max_spans})", file=out)
