"""One versioned schema for every telemetry payload the repo emits.

Before this module each surface serialized its own ad-hoc dict shape
(``Timeline.to_json``, ``engine.stats()``, ``RuntimeResult.summary()``,
serve's ``--json-out``, fleet round logs) with no version marker — evolving
any of them silently broke downstream consumers. Every JSON payload now
passes through :func:`versioned` (stamping ``schema_version``) and dataclass
records serialize through :func:`encode_record`, so there is exactly one
place to bump when the schema changes and one place consumers check.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

# Bump when any telemetry/timeline/stats payload shape changes. Consumers
# (obs_report, benchmark differs, dashboards) key their parsing off this.
SCHEMA_VERSION = 1


def encode_record(obj: Any) -> Any:
    """Canonical JSON encoding for telemetry records.

    Dataclasses (Timeline steps/migrations, audit records, span records)
    become plain dicts; non-finite floats become ``None`` (strict JSON has
    no Infinity/NaN — a bottom-rung relinquish score is ``-inf``); numpy
    scalars become native Python numbers. Containers recurse.
    """
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: encode_record(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    if isinstance(obj, dict):
        return {str(k): encode_record(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [encode_record(v) for v in obj]
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    if hasattr(obj, "item") and not isinstance(obj, (str, bytes)):
        try:
            return encode_record(obj.item())  # numpy scalar
        except (AttributeError, TypeError, ValueError):
            return obj
    return obj


def versioned(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Stamp a payload with the telemetry schema version (idempotent)."""
    out = {"schema_version": SCHEMA_VERSION}
    out.update(payload)
    return out
