"""Arbiter decision audit: why did the runtime migrate that job?

Every propose/commit/veto in ``SwanRuntime`` — plus the non-arbitrated
migration paths (energy walk-down, foreground pause/resume, device loss)
— records the full scoring context that decided it: relinquish scores per
candidate job, SLO headroom, pending proposals, the energy-loan state, the
thermal reading, and which arbitration rule fired. "Why did serve
downgrade at tick 41" becomes ``log.for_tick(41)`` instead of a debugging
session.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

from repro.obs.schema import encode_record, versioned


@dataclasses.dataclass
class AuditRecord:
    tick: int
    job: str
    # "commit": migration applied; "veto": arbiter chose the job but the
    # controller refused (ladder edge / cooldown); "pause"/"resume":
    # foreground preemption; "device-loss": fault-path remesh/degrade.
    event: str
    direction: str = ""          # "down" | "up" | ""
    rule: str = ""               # which rule fired (timeline reason string)
    from_rung: str = ""
    to_rung: str = ""
    # full scoring context at decision time
    scores: Dict[str, Optional[float]] = dataclasses.field(default_factory=dict)
    slo_headroom: Dict[str, Optional[float]] = dataclasses.field(
        default_factory=dict)
    proposals: Dict[str, str] = dataclasses.field(default_factory=dict)
    energy: Optional[Dict] = None    # {"loan_j", "available", "battery_level"}
    thermal: Optional[Dict] = None   # {"temp", "throttled"}
    detail: str = ""


class AuditLog:
    def __init__(self, max_records: int = 100_000):
        self.max_records = max_records
        self.dropped = 0
        self._records: List[AuditRecord] = []
        self._lock = threading.Lock()

    def record(self, **kw) -> Optional[AuditRecord]:
        rec = AuditRecord(**kw)
        with self._lock:
            if len(self._records) >= self.max_records:
                self.dropped += 1
                return None
            self._records.append(rec)
        return rec

    def records(self) -> List[AuditRecord]:
        with self._lock:
            return list(self._records)

    def recent(self, n: int = 20) -> List[AuditRecord]:
        with self._lock:
            return list(self._records[-n:])

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def for_tick(self, tick: int) -> List[AuditRecord]:
        return [r for r in self.records() if r.tick == tick]

    def for_job(self, job: str) -> List[AuditRecord]:
        return [r for r in self.records() if r.job == job]

    def commits(self) -> List[AuditRecord]:
        return [r for r in self.records() if r.event == "commit"]

    def to_json(self) -> Dict:
        return versioned({
            "dropped": self.dropped,
            "records": [encode_record(r) for r in self.records()],
        })

    @classmethod
    def from_json(cls, payload: Dict) -> "AuditLog":
        log = cls()
        log.dropped = int(payload.get("dropped", 0))
        for rec in payload.get("records", []):
            log.record(**{k: v for k, v in rec.items()})
        return log
