"""Labeled metrics registry: counters, gauges, histograms.

Design constraints (ISSUE 9):

* **Near-zero cost when disabled.** A disabled registry hands out one
  shared no-op handle (``NOOP``) for every metric — hot paths keep a
  reference and pay one attribute lookup + ``pass`` per update. Tests
  assert the identity so the guarantee can't silently regress.
* **One schema.** ``snapshot()`` returns a versioned dict absorbing the
  previously scattered stats surfaces; ``snapshot_line(tick)`` returns a
  flat one-line dict for per-tick JSONL streams.
* **Histograms** keep exact count/sum/min/max plus a bounded ring of
  recent samples for quantiles — enough for p50/p90/p99 on step
  latencies without unbounded memory on long fleet runs.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs.schema import versioned

LabelKey = Tuple[Tuple[str, str], ...]


class _Noop:
    """Shared do-nothing handle returned by a disabled registry."""

    __slots__ = ()

    def labels(self, **kv) -> "_Noop":
        return self

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


NOOP = _Noop()


class _Hist:
    __slots__ = ("count", "sum", "min", "max", "_ring", "_cap", "_next")

    def __init__(self, cap: int = 4096):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._ring: List[float] = []
        self._cap = cap
        self._next = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._ring) < self._cap:
            self._ring.append(v)
        else:  # ring buffer: quantiles reflect the most recent cap samples
            self._ring[self._next] = v
            self._next = (self._next + 1) % self._cap

    def quantile(self, q: float) -> Optional[float]:
        if not self._ring:
            return None
        s = sorted(self._ring)
        # nearest-rank with linear interpolation
        pos = q * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    def summary(self) -> Dict[str, Optional[float]]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "mean": None, "p50": None, "p90": None, "p99": None}
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


class _Handle:
    """A (metric, label-set) slot. Cheap to cache on the hot path."""

    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "Metric", key: LabelKey):
        self._metric = metric
        self._key = key

    def labels(self, **kv) -> "_Handle":
        return self._metric.labels(**kv)

    def inc(self, amount: float = 1.0) -> None:
        if self._metric.kind != "counter":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        with self._metric._lock:
            self._metric._series[self._key] = (
                self._metric._series.get(self._key, 0.0) + float(amount))

    def set(self, value: float) -> None:
        if self._metric.kind != "gauge":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        with self._metric._lock:
            self._metric._series[self._key] = float(value)

    def observe(self, value: float) -> None:
        if self._metric.kind != "histogram":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        with self._metric._lock:
            h = self._metric._series.get(self._key)
            if h is None:
                h = self._metric._series[self._key] = _Hist(
                    self._metric.hist_cap)
            h.observe(value)


class Metric:
    def __init__(self, name: str, kind: str, help: str = "",
                 hist_cap: int = 4096):
        self.name = name
        self.kind = kind
        self.help = help
        self.hist_cap = hist_cap
        self._series: Dict[LabelKey, object] = {}
        self._lock = threading.Lock()
        self._default = _Handle(self, ())

    def labels(self, **kv) -> _Handle:
        if not kv:
            return self._default
        key = tuple(sorted((str(k), str(v)) for k, v in kv.items()))
        return _Handle(self, key)

    # unlabeled convenience — metric doubles as its own default handle
    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    def set(self, value: float) -> None:
        self._default.set(value)

    def observe(self, value: float) -> None:
        self._default.observe(value)

    def quantile(self, q: float, **kv) -> Optional[float]:
        key = tuple(sorted((str(k), str(v)) for k, v in kv.items()))
        with self._lock:
            h = self._series.get(key)
        return h.quantile(q) if isinstance(h, _Hist) else None

    def value(self, **kv):
        key = tuple(sorted((str(k), str(v)) for k, v in kv.items()))
        with self._lock:
            v = self._series.get(key)
        return v.summary() if isinstance(v, _Hist) else v

    def series(self) -> List[Dict]:
        out = []
        with self._lock:
            items = list(self._series.items())
        for key, val in items:
            row: Dict = {"labels": dict(key)}
            if isinstance(val, _Hist):
                row.update(val.summary())
            else:
                row["value"] = val
            out.append(row)
        return out


def _flat_key(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Registry of named metrics; disabled ⇒ every lookup returns ``NOOP``."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: Dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str, **extra):
        if not self.enabled:
            return NOOP
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = Metric(name, kind, help, **extra)
            elif m.kind != kind:
                raise TypeError(
                    f"metric {name!r} registered as {m.kind}, requested {kind}")
            return m

    def counter(self, name: str, help: str = ""):
        return self._get(name, "counter", help)

    def gauge(self, name: str, help: str = ""):
        return self._get(name, "gauge", help)

    def histogram(self, name: str, help: str = "", max_samples: int = 4096):
        return self._get(name, "histogram", help, hist_cap=max_samples)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> Dict:
        """Full structured dump — versioned, JSON-serializable."""
        with self._lock:
            metrics = list(self._metrics.values())
        return versioned({
            "metrics": {
                m.name: {"kind": m.kind, "help": m.help,
                         "series": m.series()}
                for m in metrics
            },
        })

    def snapshot_line(self, tick) -> Dict:
        """Flat one-line dict for a JSONL stream: name{k=v} -> value."""
        with self._lock:
            metrics = list(self._metrics.values())
        flat: Dict[str, object] = {}
        for m in metrics:
            with m._lock:
                items = list(m._series.items())
            for key, val in items:
                fk = _flat_key(m.name, key)
                flat[fk] = val.summary() if isinstance(val, _Hist) else val
        return {"tick": tick, "metrics": flat}
