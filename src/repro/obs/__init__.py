"""repro.obs — one telemetry plane for the whole reproduction.

Bundles the three instruments from ISSUE 9 behind a single switch:

* :class:`~repro.obs.metrics.MetricsRegistry` — labeled counters /
  gauges / histograms with shared no-op handles when disabled;
* :class:`~repro.obs.trace.SpanTracer` — thread-safe contextmanager
  spans on monotonic clocks, exported as Chrome-trace JSON;
* :class:`~repro.obs.audit.AuditLog` — the arbiter decision audit.

The process-global instance starts **disabled** (every hot-path call is
an enabled-check + shared no-op object), so importing this module from
kernels/engines costs nothing. CLIs flip it on via :func:`enable` when
``--telemetry-out`` is passed; tests swap it with :func:`set_telemetry`.
Components always fetch it lazily (``obs.get_telemetry()``) so enabling
works regardless of construction order.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

from repro.obs.audit import AuditLog, AuditRecord
from repro.obs.metrics import NOOP, MetricsRegistry
from repro.obs.schema import SCHEMA_VERSION, encode_record, versioned
from repro.obs.trace import SpanTracer

__all__ = [
    "SCHEMA_VERSION", "encode_record", "versioned", "NOOP",
    "MetricsRegistry", "SpanTracer", "AuditLog", "AuditRecord",
    "Telemetry", "get_telemetry", "set_telemetry", "enable", "disable",
]


class Telemetry:
    """Metrics + tracer + audit under one enabled flag."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.metrics = MetricsRegistry(enabled=enabled)
        self.tracer = SpanTracer(enabled=enabled)
        self.audit = AuditLog()
        self.snapshots: List[Dict] = []  # per-tick JSONL metric lines

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def snap(self, tick) -> None:
        """Append one flat metrics line for tick ``tick`` (JSONL stream)."""
        if self.enabled:
            self.snapshots.append(self.metrics.snapshot_line(tick))

    # ------------------------------------------------------------------
    def save(self, outdir: str) -> Dict[str, str]:
        """Write the full telemetry bundle under ``outdir``.

        ``metrics.jsonl`` — versioned header line then one line per tick;
        ``spans.jsonl`` — raw span records; ``trace.json`` — Chrome-trace
        (Perfetto-loadable); ``audit.json`` — arbiter decision audit.
        """
        os.makedirs(outdir, exist_ok=True)
        paths = {}

        p = os.path.join(outdir, "metrics.jsonl")
        with open(p, "w") as f:
            f.write(json.dumps(versioned({"stream": "metrics"})) + "\n")
            for line in self.snapshots:
                f.write(json.dumps(encode_record(line)) + "\n")
            # final snapshot so non-tick-driven runs (plain serve loop)
            # still land their terminal metric values in the stream
            f.write(json.dumps(encode_record(
                self.metrics.snapshot_line("final"))) + "\n")
        paths["metrics"] = p

        p = os.path.join(outdir, "spans.jsonl")
        with open(p, "w") as f:
            f.write(json.dumps(versioned({"stream": "spans"})) + "\n")
            for rec in self.tracer.to_records():
                f.write(json.dumps(rec) + "\n")
        paths["spans"] = p

        p = os.path.join(outdir, "trace.json")
        self.tracer.save_chrome_trace(p)
        paths["trace"] = p

        p = os.path.join(outdir, "audit.json")
        with open(p, "w") as f:
            json.dump(self.audit.to_json(), f, indent=1)
        paths["audit"] = p
        return paths

    def debug_dump(self, file=None, last: int = 20) -> None:
        """Dump live span stacks + recent spans/audit to stderr.

        Called from the SIGALRM timeout hook in ``tests/conftest.py`` so a
        hung test fails with context instead of a bare TimeoutError.
        """
        out = file if file is not None else sys.stderr
        if not self.enabled:
            print("[obs] telemetry disabled (enable with repro.obs.enable() "
                  "or a --telemetry-out flag)", file=out)
            return
        self.tracer.debug_dump(file=out, last=last)
        recent = self.audit.recent(last)
        if recent:
            print(f"[obs] last {len(recent)} audit records:", file=out)
            for r in recent:
                print(f"[obs]   tick {r.tick}: {r.job} {r.event} "
                      f"{r.direction or '-'} rule={r.rule or '-'} "
                      f"{r.from_rung}->{r.to_rung}", file=out)
        if self.snapshots:
            print(f"[obs] latest metrics snapshot: "
                  f"{json.dumps(encode_record(self.snapshots[-1]))}",
                  file=out)


_GLOBAL = Telemetry(enabled=False)


def get_telemetry() -> Telemetry:
    return _GLOBAL


def set_telemetry(tel: Telemetry) -> Telemetry:
    """Install ``tel`` as the process-global instance; returns the old one."""
    global _GLOBAL
    old = _GLOBAL
    _GLOBAL = tel
    return old


def enable() -> Telemetry:
    """Install and return a fresh enabled global Telemetry."""
    tel = Telemetry(enabled=True)
    set_telemetry(tel)
    return tel


def disable() -> Telemetry:
    """Install and return a fresh disabled global Telemetry."""
    tel = Telemetry(enabled=False)
    set_telemetry(tel)
    return tel
