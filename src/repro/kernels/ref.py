"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_rmsnorm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def ref_depthwise_conv(x, w):
    """x: (B,H,W,C); w: (kh,kw,C); stride 1, SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w[:, :, None, :], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=x.shape[-1])


def ref_flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: (B,S,H,hd); k,v: (B,S,H,hd) (heads pre-broadcast); fp32 softmax."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq) + q_offset
        mask = jnp.arange(Sk)[None, :] <= qi[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
