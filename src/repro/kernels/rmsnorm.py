"""Fused RMSNorm Pallas kernels: forward + custom_vjp backward.

Tiling: rows in the sublane dim, the full feature dim in lanes. One grid step
normalizes a (block_rows, d) tile entirely in VMEM — a single HBM read and
write per element (XLA's unfused version reads x twice: once for the moment,
once for the scale-multiply).

The backward is fused the same way. Residuals are just (x, scale): the
rsqrt moment is recomputed in-tile (cheaper than a second HBM stream for a
saved rstd). With ``r = rsqrt(mean(x^2)+eps)`` and ``gs = g*scale``:

  dx     = r*gs - x * r^3 * mean(gs*x, -1)
  dscale = sum_rows(g * x * r)

``dscale`` needs a cross-tile reduction, so the kernel emits per-tile
partials of shape (n_tiles, d) and the wrapper sums them — an O(n_tiles*d)
tensor, not O(rows*d).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import divisor_block, resolve_interpret


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_bwd_kernel(x_ref, scale_ref, g_ref, dx_ref, dsp_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    gs = g * s
    dot = jnp.mean(gs * x, axis=-1, keepdims=True)
    dx_ref[...] = (r * gs - x * (r * r * r) * dot).astype(dx_ref.dtype)
    dsp_ref[...] = jnp.sum(g * x * r, axis=0, keepdims=True)


def _fwd_call(x2, scale, *, eps: float, br: int, interpret: bool):
    rows, d = x2.shape
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x2.dtype),
        interpret=interpret,
    )(x2, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rmsnorm2d(x2, scale, eps, br, interpret):
    return _fwd_call(x2, scale, eps=eps, br=br, interpret=interpret)


def _rmsnorm2d_fwd(x2, scale, eps, br, interpret):
    return _fwd_call(x2, scale, eps=eps, br=br, interpret=interpret), (x2, scale)


def _rmsnorm2d_bwd(eps, br, interpret, res, g):
    x2, scale = res
    rows, d = x2.shape
    n_blocks = rows // br
    dx, dsp = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x2.dtype),
            jax.ShapeDtypeStruct((n_blocks, d), jnp.float32),
        ],
        interpret=interpret,
    )(x2, scale, g)
    return dx, dsp.sum(0).astype(scale.dtype)


_rmsnorm2d.defvjp(_rmsnorm2d_fwd, _rmsnorm2d_bwd)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: Optional[bool] = None):
    """x: (..., d); scale: (d,). Differentiable (custom_vjp backward kernel)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    out = _rmsnorm2d(x2, scale, eps, divisor_block(rows, block_rows),
                     resolve_interpret(interpret))
    return out.reshape(orig_shape)
