"""Fused RMSNorm Pallas kernel.

Tiling: rows in the sublane dim, the full feature dim in lanes. One grid step
normalizes a (block_rows, d) tile entirely in VMEM — a single HBM read and
write per element (XLA's unfused version reads x twice: once for the moment,
once for the scale-multiply).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256, interpret: bool = True):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
