"""Depthwise (kh x kw) conv Pallas kernel — the paper's hot op.

Swan's §3.1 observation: depthwise conv is memory-bound, and on ARM CPUs
multi-core execution cache-thrashes. The TPU-native adaptation (DESIGN.md §2)
is to tile for the HBM->VMEM->VREG hierarchy instead of the GPU refactoring
trick the paper cites [42]: channels ride the 128-wide lane dim (depthwise is
elementwise-in-channel, so lanes never interact), a (batch, channel-block)
grid keeps each tile's working set resident in VMEM, and the kh*kw taps
become shifted multiply-accumulates over the resident tile — exactly one HBM
read and one HBM write per element, the memory-roofline optimum. No
cross-tile traffic, hence nothing to thrash.

Stride 1, SAME padding (the shape inside MobileNet/ShuffleNet residual units).
Rows are pre-padded outside the kernel so all tap slices are static.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import divisor_block, resolve_interpret


def _dw_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, H: int, W: int):
    """x block: (1, H+kh-1, W, Cb) row-padded; w: (kh,kw,Cb); out: (1,H,W,Cb)."""
    x = x_ref[0].astype(jnp.float32)  # (H+kh-1, W, Cb)
    cb = x.shape[-1]
    acc = jnp.zeros((H, W, cb), jnp.float32)
    pw = (kw - 1) // 2
    for a in range(kh):
        rows = jax.lax.slice_in_dim(x, a, a + H, axis=0)  # (H, W, Cb)
        for c in range(kw):
            tap = w_ref[a, c, :].astype(jnp.float32)
            ox = c - pw
            lo, hi = max(0, -ox), W - max(0, ox)
            if hi <= lo:
                continue
            src = jax.lax.slice_in_dim(rows, lo + ox, hi + ox, axis=1) * tap
            contrib = jnp.zeros((H, W, cb), jnp.float32)
            contrib = jax.lax.dynamic_update_slice_in_dim(contrib, src, lo, axis=1)
            acc = acc + contrib
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("channel_block", "interpret"))
def depthwise_conv(x, w, *, channel_block: int = 128,
                   interpret: Optional[bool] = None):
    """x: (B,H,W,C); w: (kh,kw,C); stride 1, SAME padding, odd kernel dims."""
    interpret = resolve_interpret(interpret)
    B, H, W, C = x.shape
    kh, kw = w.shape[0], w.shape[1]
    ph = (kh - 1) // 2
    cb = divisor_block(C, channel_block)
    xp = jnp.pad(x, ((0, 0), (ph, ph), (0, 0), (0, 0)))
    grid = (B, C // cb)
    return pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, H=H, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H + kh - 1, W, cb), lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((kh, kw, cb), lambda b, c: (0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, cb), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, C), x.dtype),
        interpret=interpret,
    )(xp, w)
