"""Flash-attention forward Pallas kernel (prefill/train hot-spot).

Grid: (batch, heads, q-blocks). Each invocation owns one (block_q, hd) query
tile in VMEM and streams KV in (block_k, hd) tiles with the online-softmax
recurrence entirely in registers/VMEM — the (Sq, Sk) score matrix never
touches HBM. block_q/block_k default to 128 to match the MXU tile; hd rides
the lane dim.

Heads are pre-broadcast by the wrapper (GQA handled in ops.py), keeping the
kernel a pure MHA primitive.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sk: int,
                  causal: bool, q_offset: int, scale: float):
    """q: (1,1,block_q,hd); k,v: (1,1,Sk,hd); o: (1,1,block_q,hd)."""
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
    bq = q.shape[0]
    hd = q.shape[1]
    n_kv = sk // block_k

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], j * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], j * block_k, block_k, 0)
        s = q @ k.astype(jnp.float32).T  # (bq, bk)
        if causal:
            q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0) + q_offset
            k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(k_idx <= q_idx, s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "block_q",
                                             "block_k", "interpret"))
def flash_attention_mha(q, k, v, *, causal: bool = True, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128, interpret: bool = True):
    """q,k,v: (B,H,S,hd) same head count. Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Sk)
    while Sk % bk:
        bk -= 1
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, Sq // bq)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_k=bk, sk=Sk, causal=causal,
                          q_offset=q_offset, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
