"""Flash-attention Pallas kernels: fused forward + custom_vjp backward.

This is the training-grade attention hot path (``impl="pallas"``). Three
kernels share one tiling scheme:

  forward   grid (B, H, q_blocks, kv_blocks)   -> o, lse
  dq        grid (B, H, q_blocks, kv_blocks)   -> dq
  dkv       grid (B, H, kv_blocks, q_blocks)   -> dk, dv

Tiling / residual layout
------------------------
The innermost grid dimension iterates sequentially ("arbitrary" semantics on
TPU), so the online-softmax state — running max ``m``, normalizer ``l`` and
the output accumulator — lives in VMEM scratch that carries across KV tiles
of one query block. KV streams through the grid via BlockSpec index maps in
(block_k, hd) tiles: per grid step the kernel holds one (block_q, hd) query
tile and one (block_k, hd) KV tile, and the (Sq, Sk) score matrix never
exists anywhere — not in HBM, not in VMEM. The forward additionally emits the
log-sum-exp rows ``lse = m + log(l)`` of shape (B, H, Sq); together with the
saved output ``o`` this is the entire backward residual, O(B*H*Sq) instead of
the O(Sq*Sk) probability matrix.

Causal block skipping
---------------------
For causal attention, KV tiles entirely above the diagonal contribute
nothing. Their grid steps are predicated out with ``pl.when`` AND their
BlockSpec index maps clamp to the last needed tile, so the pipeline re-fetches
a resident block instead of DMA-ing a dead one — the compute drops from
Sq*Sk to the ~Sq*Sk/2 lower-triangular FLOPs (the paper-shape win at long
Sq). The dkv kernel mirrors this by skipping query tiles entirely *below*
its KV tile's diagonal band.

Backward derivation (AttentionEngine-style online recomputation): with
``p = exp(s - lse)`` and ``delta = rowsum(do * o)``,

  dv = p^T @ do
  ds = p * (do @ v^T - delta)
  dq = scale * ds @ k          (accumulated over KV tiles)
  dk = scale * ds^T @ q        (accumulated over Q tiles)

``jax.custom_vjp`` wires these in so ``jax.grad`` through ``impl="pallas"``
never differentiates the Pallas forward. Heads are pre-broadcast by the
wrapper (GQA handled in ops.py, whose broadcast transpose sums dk/dv over the
query-head group).

Decode kernel
-------------
``flash_decode`` is the serving-path sibling: one query *token* per (batch,
kv-head) program, grid (B, K, kv_blocks). The query block holds the whole
GQA group — (G, hd) query rows that share one KV head — so each KV tile is
DMA'd once per group instead of once per query head. Per-sequence valid
lengths arrive via scalar prefetch (``PrefetchScalarGridSpec``): the KV
index maps clamp tiles past ``lengths[b]`` to the last live tile (re-fetch
of a resident block, no dead DMA) and ``pl.when`` predicates their compute
away, so a ragged continuous batch streams only the cache it actually has.
The MLA variant runs in the latent space (k = [latent | k_rope], v = latent)
via the same kernel with K=1, G=H and an explicit softmax scale.

``flash_decode_paged`` is the paged-KV sibling: the cache lives in a
``(num_blocks, block_size, K, hd)`` pool shared by every sequence and each
sequence names its blocks via a ``(B, max_blocks_per_seq)`` int32 block
table. The table rides scalar prefetch next to ``lengths``, so the KV
BlockSpec index maps translate logical tile j -> physical block
``table[b, j]`` before the DMA is issued — same grid, same VMEM carry, same
clamp-and-predicate treatment of tiles past ``lengths[b]`` as the
contiguous kernel, just one extra indirection in the index map.

bf16 accumulation (``REPRO_ATTN_BF16`` / ``lowp=``): dot-product inputs drop
to bf16 — halving the KV bytes the MXU pulls per tile — while online-softmax
statistics and the output accumulator stay f32, matching the chunked path.

Remaining (tracked in ROADMAP.md): dropout, sliding-window masking.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import (attn_bf16, divisor_block,
                                   resolve_interpret, tpu_compiler_params)

NEG_INF = -1e30
_LANES = 128  # TPU lane width: m/l scratch rides (block_q, 128)


def _causal_mask(s, qi, ji, bq, bk, q_offset):
    q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
    k_idx = ji * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    return k_idx <= q_idx


def _grid_params(interpret: bool):
    """dimension_semantics: batch/head/outer-block parallel, inner sequential."""
    if interpret:
        return {}
    return {"compiler_params": tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                block_q: int, block_k: int, causal: bool, q_offset: int,
                scale: float, n_kv: int, lowp: bool):
    qi, ji = pl.program_id(2), pl.program_id(3)

    @pl.when(ji == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    if causal:
        j_last = jnp.minimum(
            (qi * block_q + block_q - 1 + q_offset) // block_k, n_kv - 1)
        live = ji <= j_last
    else:
        live = ji >= 0  # always true; keeps one code path

    @pl.when(live)
    def _():
        cdt = jnp.bfloat16 if lowp else jnp.float32
        q = (q_ref[0, 0].astype(jnp.float32) * scale).astype(cdt)
        k = k_ref[0, 0].astype(cdt)
        v = v_ref[0, 0].astype(cdt)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            s = jnp.where(_causal_mask(s, qi, ji, block_q, block_k, q_offset),
                          s, NEG_INF)
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_blk = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(cdt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ji == n_kv - 1)
    def _():
        m = m_scr[:, :1]
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m + jnp.log(l))[:, 0]


def _fwd_call(q, k, v, *, causal: bool, q_offset: int, bq: int, bk: int,
              interpret: bool, lowp: bool = False):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    n_q, n_kv = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    def kv_index(b, h, i, j):
        if causal:  # clamp dead above-diagonal tiles to the last live one
            j = jnp.minimum(j, (i * bq + bq - 1 + q_offset) // bk)
        return (b, h, j, 0)

    return pl.pallas_call(
        functools.partial(_fwd_kernel, block_q=bq, block_k=bk, causal=causal,
                          q_offset=q_offset, scale=scale, n_kv=n_kv, lowp=lowp),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
        **_grid_params(interpret),
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward: dq (stream KV per query block)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, block_q: int, block_k: int, causal: bool,
               q_offset: int, scale: float, n_kv: int, lowp: bool):
    qi, ji = pl.program_id(2), pl.program_id(3)

    @pl.when(ji == 0)
    def _():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    if causal:
        live = ji <= jnp.minimum(
            (qi * block_q + block_q - 1 + q_offset) // block_k, n_kv - 1)
    else:
        live = ji >= 0

    @pl.when(live)
    def _():
        cdt = jnp.bfloat16 if lowp else jnp.float32
        q = q_ref[0, 0].astype(cdt)
        k = k_ref[0, 0].astype(cdt)
        v = v_ref[0, 0].astype(cdt)
        do = do_ref[0, 0].astype(cdt)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        p = jnp.exp(s - lse)
        if causal:
            p = jnp.where(_causal_mask(s, qi, ji, block_q, block_k, q_offset),
                          p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = (p * (dp - delta)).astype(cdt)
        dq_scr[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ji == n_kv - 1)
    def _():
        dq_ref[0, 0] = dq_scr[...]


# ---------------------------------------------------------------------------
# backward: dk/dv (stream Q per KV block)
# ---------------------------------------------------------------------------


def _dkv_kernel(k_ref, v_ref, q_ref, do_ref, lse_ref, delta_ref, dk_ref,
                dv_ref, dk_scr, dv_scr, *, block_q: int, block_k: int,
                causal: bool, q_offset: int, scale: float, n_q: int,
                lowp: bool):
    ji, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    # causal: query tiles whose last row is still above this KV tile's first
    # column see none of it — skip them
    live = ((qi + 1) * block_q - 1 + q_offset >= ji * block_k) if causal else qi >= 0

    @pl.when(live)
    def _():
        cdt = jnp.bfloat16 if lowp else jnp.float32
        q = q_ref[0, 0].astype(cdt)
        k = k_ref[0, 0].astype(cdt)
        v = v_ref[0, 0].astype(cdt)
        do = do_ref[0, 0].astype(cdt)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        s = scale * jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        p = jnp.exp(s - lse)
        if causal:
            p = jnp.where(_causal_mask(s, qi, ji, block_q, block_k, q_offset),
                          p, 0.0)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(cdt), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = (p * (dp - delta)).astype(cdt)
        dk_scr[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _():
        dk_ref[0, 0] = dk_scr[...]
        dv_ref[0, 0] = dv_scr[...]


def _bwd_call(q, k, v, o, lse, do, *, causal: bool, q_offset: int, bq: int,
              bk: int, interpret: bool, lowp: bool = False):
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    n_q, n_kv = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    def kv_index(b, h, i, j):
        if causal:
            j = jnp.minimum(j, (i * bq + bq - 1 + q_offset) // bk)
        return (b, h, j, 0)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_q=bq, block_k=bk, causal=causal,
                          q_offset=q_offset, scale=scale, n_kv=n_kv, lowp=lowp),
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
        **_grid_params(interpret),
    )(q, k, v, do, lse, delta)

    def q_index(b, h, j, i):
        if causal:  # clamp dead below-band tiles to the first live one
            i = jnp.maximum(i, (j * bk - q_offset) // bq)
            i = jnp.clip(i, 0, n_q - 1)
        return (b, h, i, 0)

    def q_row_index(b, h, j, i):
        bidx = q_index(b, h, j, i)
        return (bidx[0], bidx[1], bidx[2])

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=bq, block_k=bk, causal=causal,
                          q_offset=q_offset, scale=scale, n_q=n_q, lowp=lowp),
        grid=(B, H, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bq, hd), q_index),
            pl.BlockSpec((1, 1, bq, hd), q_index),
            pl.BlockSpec((1, 1, bq), q_row_index),
            pl.BlockSpec((1, 1, bq), q_row_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sk, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sk, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
        **_grid_params(interpret),
    )(k, v, q, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_mha(q, k, v, causal, q_offset, bq, bk, interpret, lowp):
    o, _ = _fwd_call(q, k, v, causal=causal, q_offset=q_offset, bq=bq, bk=bk,
                     interpret=interpret, lowp=lowp)
    return o


def _flash_mha_fwd(q, k, v, causal, q_offset, bq, bk, interpret, lowp):
    o, lse = _fwd_call(q, k, v, causal=causal, q_offset=q_offset, bq=bq, bk=bk,
                       interpret=interpret, lowp=lowp)
    return o, (q, k, v, o, lse)


def _flash_mha_bwd(causal, q_offset, bq, bk, interpret, lowp, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_call(q, k, v, o, lse, do, causal=causal,
                           q_offset=q_offset, bq=bq, bk=bk, interpret=interpret,
                           lowp=lowp)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def flash_attention_mha(q, k, v, *, causal: bool = True, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None,
                        lowp: Optional[bool] = None):
    """q,k,v: (B,H,S,hd) same head count. Returns (B,H,Sq,hd); differentiable.

    ``interpret`` and ``lowp`` (bf16 dot inputs, REPRO_ATTN_BF16) resolve
    eagerly here — outside any jit — so env flips take effect per call.
    """
    if q.shape[-1] != k.shape[-1] or q.shape[-1] != v.shape[-1]:
        # MLA prefill has qk_dim != v_dim; the tiled kernel assumes one head
        # dim throughout, so a mismatch silently produces garbage — refuse
        # loudly instead (models.attention.mla_forward falls back to chunked)
        raise ValueError(
            f"flash_attention_mha needs matching q/k/v head dims, got "
            f"q={q.shape[-1]} k={k.shape[-1]} v={v.shape[-1]}; use the "
            f"'chunked' impl for asymmetric-head attention (e.g. MLA prefill)")
    _, _, Sq, _ = q.shape
    Sk = k.shape[2]
    bq = divisor_block(Sq, block_q)
    bk = divisor_block(Sk, block_k)
    return _flash_mha(q, k, v, causal, q_offset, bq, bk,
                      resolve_interpret(interpret), attn_bf16(lowp))


def flash_attention_fwd_lse(q, k, v, *, causal: bool = True, q_offset: int = 0,
                            block_q: int = 128, block_k: int = 128,
                            interpret: Optional[bool] = None,
                            lowp: Optional[bool] = None):
    """Forward that also returns the (B,H,Sq) log-sum-exp residual rows."""
    Sq, Sk = q.shape[2], k.shape[2]
    return _fwd_call(q, k, v, causal=causal, q_offset=q_offset,
                     bq=divisor_block(Sq, block_q),
                     bk=divisor_block(Sk, block_k),
                     interpret=resolve_interpret(interpret),
                     lowp=attn_bf16(lowp))


# ---------------------------------------------------------------------------
# decode: single query token, per-sequence valid lengths
# ---------------------------------------------------------------------------


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_k: int, scale: float, n_kv: int, lowp: bool):
    b, ji = pl.program_id(0), pl.program_id(2)

    @pl.when(ji == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(ji * block_k < length)
    def _():
        cdt = jnp.bfloat16 if lowp else jnp.float32
        q = (q_ref[0, 0].astype(jnp.float32) * scale).astype(cdt)  # (G, hd)
        k = k_ref[0, :, 0].astype(cdt)                             # (bk, hd)
        v = v_ref[0, :, 0].astype(cdt)                             # (bk, hdv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kv_idx = ji * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_idx < length, s, NEG_INF)
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(cdt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ji == n_kv - 1)
    def _():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _decode_grid_params(interpret: bool):
    if interpret:
        return {}
    return {"compiler_params": tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))}


def flash_decode(q, k, v, lengths, *, scale: Optional[float] = None,
                 block_k: int = 256, interpret: Optional[bool] = None,
                 lowp: Optional[bool] = None):
    """Single-query flash decode over a ragged KV cache.

    q: (B, K, G, hd) — one new token's query heads, grouped so the G query
       heads sharing KV head k sit together (GQA: G = H // K; MHA: G = 1).
    k: (B, Smax, K, hd)   v: (B, Smax, K, hdv) — the KV cache buffers.
    lengths: (B,) int32 — row b attends to cache positions < lengths[b];
       rows with length 0 (idle serving slots) produce zeros, not NaNs.

    Grid is (B, K, kv_blocks) with the online-softmax carry in VMEM scratch;
    ``lengths`` rides scalar prefetch so the KV BlockSpec index maps clamp
    tiles past the valid length to the last live tile (no dead-cache DMA) and
    their grid steps are compute-predicated away. Returns (B, K, G, hdv).
    Serving path only: no custom_vjp (decode never backpropagates).
    """
    B, K, G, hd = q.shape
    Smax = k.shape[1]
    hdv = v.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    bk = divisor_block(Smax, block_k)
    n_kv = Smax // bk
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    interp = resolve_interpret(interpret)

    def q_index(b, kh, j, len_ref):
        return (b, kh, 0, 0)

    def kv_index(b, kh, j, len_ref):
        # clamp dead tiles past lengths[b] to the last live one: the pipeline
        # re-fetches a resident block instead of DMA-ing cache it won't read
        j = jnp.minimum(j, jnp.maximum(pl.cdiv(len_ref[b], bk) - 1, 0))
        return (b, j, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_index),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
            pl.BlockSpec((1, bk, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hdv), q_index),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, hdv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, block_k=bk, scale=scale, n_kv=n_kv,
                          lowp=attn_bf16(lowp)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hdv), q.dtype),
        interpret=interp,
        **_decode_grid_params(interp),
    )(lengths, q, k, v)


# ---------------------------------------------------------------------------
# paged decode: KV in a block pool, indexed through a block table
# ---------------------------------------------------------------------------


def _decode_paged_kernel(len_ref, tbl_ref, *rest, **kw):
    # the block table is consumed entirely by the BlockSpec index maps; the
    # kernel body is the contiguous single-query kernel unchanged (online
    # softmax over tiles, compute predicated past lengths[b])
    del tbl_ref
    _decode_kernel(len_ref, *rest, **kw)


def flash_decode_paged(q, k_pool, v_pool, block_table, lengths, *,
                       scale: Optional[float] = None,
                       interpret: Optional[bool] = None,
                       lowp: Optional[bool] = None):
    """Single-query flash decode over a paged (block-pooled) KV cache.

    q: (B, K, G, hd) — grouped query heads, as in ``flash_decode``.
    k_pool: (num_blocks, block_size, K, hd)  v_pool: (..., hdv) — physical
       KV blocks shared by all sequences (no batch dimension).
    block_table: (B, T) int32 — logical block j of sequence b lives in
       physical block ``block_table[b, j]``; rows may point unused tail
       entries at any valid block (they are clamped and predicated away).
    lengths: (B,) int32 — row b attends to virtual positions < lengths[b]
       (position p lives at offset p % block_size of logical block
       p // block_size); rows with length 0 produce zeros.

    Grid is (B, K, T): the kernel tile IS the pool block, so each grid step
    DMAs exactly one physical block, located by the scalar-prefetched table.
    Tiles past ``lengths[b]`` clamp to the last live logical block (re-fetch
    of a resident physical block, no dead DMA) and their compute is
    predicated away — identical math to ``flash_decode`` on the contiguous
    cache the table describes. Returns (B, K, G, hdv).
    """
    B, K, G, hd = q.shape
    num_blocks, bs = k_pool.shape[0], k_pool.shape[1]
    hdv = v_pool.shape[-1]
    T = block_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    table = jnp.clip(jnp.asarray(block_table, jnp.int32), 0, num_blocks - 1)
    interp = resolve_interpret(interpret)

    def q_index(b, kh, j, len_ref, tbl_ref):
        return (b, kh, 0, 0)

    def kv_index(b, kh, j, len_ref, tbl_ref):
        # clamp dead tiles past lengths[b] to the last live logical block,
        # then translate logical -> physical through the block table
        j = jnp.minimum(j, jnp.maximum(pl.cdiv(len_ref[b], bs) - 1, 0))
        return (tbl_ref[b, j], 0, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, T),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_index),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, bs, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hdv), q_index),
        scratch_shapes=[
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, _LANES), jnp.float32),
            pltpu.VMEM((G, hdv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_paged_kernel, block_k=bs, scale=scale,
                          n_kv=T, lowp=attn_bf16(lowp)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hdv), q.dtype),
        interpret=interp,
        **_decode_grid_params(interp),
    )(lengths, table, q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# speculative decode: q-block of draft positions, causal masking in the tile
# ---------------------------------------------------------------------------


def _spec_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                        acc_scr, *, block_k: int, scale: float, n_kv: int,
                        n_draft: int, group: int, lowp: bool):
    """The single-query decode kernel grown to a q-block of draft positions.

    The q block holds the S = n_draft draft positions of one (batch, KV
    head) program, flattened together with the G-row query group to
    (S*G, hd) rows where row r = qi*G + g. ``len_ref[b]`` is the BASE cache
    length — the valid count *before* the draft KVs were scattered at
    positions base..base+S-1 — so draft position qi attends cache positions
    < base + qi + 1: the per-row causal mask lives inside the tile, and the
    online-softmax carry is per row exactly as in ``_decode_kernel``.
    """
    b, ji = pl.program_id(0), pl.program_id(2)

    @pl.when(ji == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = len_ref[b]

    @pl.when(ji * block_k < base + n_draft)
    def _():
        cdt = jnp.bfloat16 if lowp else jnp.float32
        q = q_ref[0, 0].reshape(n_draft * group, q_ref.shape[-1])
        q = (q.astype(jnp.float32) * scale).astype(cdt)            # (S*G, hd)
        k = k_ref[0, :, 0].astype(cdt)                             # (bk, hd)
        v = v_ref[0, :, 0].astype(cdt)                             # (bk, hdv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kv_idx = ji * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        s = jnp.where(kv_idx < base + qi + 1, s, NEG_INF)
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(cdt), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ji == n_kv - 1)
    def _():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).reshape(
            n_draft, group, acc_scr.shape[-1]).astype(o_ref.dtype)


def flash_decode_spec(q, k, v, lengths, *, scale: Optional[float] = None,
                      block_k: int = 256, interpret: Optional[bool] = None,
                      lowp: Optional[bool] = None):
    """Multi-token speculative verify over a ragged contiguous KV cache.

    q: (B, K, S, G, hd) — S draft positions' query heads, grouped per KV
       head as in ``flash_decode`` (GQA: G = H // K).
    k: (B, Smax, K, hd)   v: (B, Smax, K, hdv) — cache buffers with the S
       draft tokens' KV already scattered at positions
       lengths[b]..lengths[b]+S-1.
    lengths: (B,) int32 — BASE valid counts (before the drafts); draft
       position qi of row b attends cache positions < lengths[b] + qi + 1.

    One grid step per KV tile verifies all S positions at once: same
    (B, K, kv_blocks) grid, scalar-prefetch clamp, and predication as the
    single-query kernel, with the causal mask applied per q-row inside the
    tile. Returns (B, K, S, G, hdv). Serving path only (no custom_vjp).
    """
    B, K, S, G, hd = q.shape
    Smax = k.shape[1]
    hdv = v.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    bk = divisor_block(Smax, block_k)
    n_kv = Smax // bk
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    interp = resolve_interpret(interpret)

    def q_index(b, kh, j, len_ref):
        return (b, kh, 0, 0, 0)

    def kv_index(b, kh, j, len_ref):
        # the last live tile now covers the drafts too: clamp at base + S
        j = jnp.minimum(j, jnp.maximum(pl.cdiv(len_ref[b] + S, bk) - 1, 0))
        return (b, j, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, S, G, hd), q_index),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
            pl.BlockSpec((1, bk, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, S, G, hdv), q_index),
        scratch_shapes=[
            pltpu.VMEM((S * G, _LANES), jnp.float32),
            pltpu.VMEM((S * G, _LANES), jnp.float32),
            pltpu.VMEM((S * G, hdv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_spec_decode_kernel, block_k=bk, scale=scale,
                          n_kv=n_kv, n_draft=S, group=G, lowp=attn_bf16(lowp)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, S, G, hdv), q.dtype),
        interpret=interp,
        **_decode_grid_params(interp),
    )(lengths, q, k, v)


def _spec_decode_paged_kernel(len_ref, tbl_ref, *rest, **kw):
    # as in the single-query paged kernel, the table is consumed entirely by
    # the BlockSpec index maps; the body is the contiguous spec kernel
    del tbl_ref
    _spec_decode_kernel(len_ref, *rest, **kw)


def flash_decode_spec_paged(q, k_pool, v_pool, block_table, lengths, *,
                            scale: Optional[float] = None,
                            interpret: Optional[bool] = None,
                            lowp: Optional[bool] = None):
    """Multi-token speculative verify over a paged (block-pooled) KV cache.

    q: (B, K, S, G, hd) — S draft positions, grouped as in
       ``flash_decode_spec``. k_pool/v_pool: (num_blocks, block_size, K, .)
       physical blocks; block_table: (B, T) int32 with blocks mapped through
       position lengths[b] + S - 1 (the engine appends draft positions before
       the verify call, so boundary blocks already exist).
    lengths: (B,) int32 BASE valid counts, as in ``flash_decode_spec``.

    Grid is (B, K, T) with the tile = pool block; dead tiles clamp to the
    last logical block covering base + S. Returns (B, K, S, G, hdv).
    """
    B, K, S, G, hd = q.shape
    num_blocks, bs = k_pool.shape[0], k_pool.shape[1]
    hdv = v_pool.shape[-1]
    T = block_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    table = jnp.clip(jnp.asarray(block_table, jnp.int32), 0, num_blocks - 1)
    interp = resolve_interpret(interpret)

    def q_index(b, kh, j, len_ref, tbl_ref):
        return (b, kh, 0, 0, 0)

    def kv_index(b, kh, j, len_ref, tbl_ref):
        j = jnp.minimum(j, jnp.maximum(pl.cdiv(len_ref[b] + S, bs) - 1, 0))
        return (tbl_ref[b, j], 0, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, T),
        in_specs=[
            pl.BlockSpec((1, 1, S, G, hd), q_index),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, bs, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, S, G, hdv), q_index),
        scratch_shapes=[
            pltpu.VMEM((S * G, _LANES), jnp.float32),
            pltpu.VMEM((S * G, _LANES), jnp.float32),
            pltpu.VMEM((S * G, hdv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_spec_decode_paged_kernel, block_k=bs, scale=scale,
                          n_kv=T, n_draft=S, group=G, lowp=attn_bf16(lowp)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, S, G, hdv), q.dtype),
        interpret=interp,
        **_decode_grid_params(interp),
    )(lengths, table, q, k_pool, v_pool)
