"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on a real TPU backend
they lower via Mosaic (interpret=False). The model code calls these through
``impl="pallas"`` switches; the default dry-run path uses the pure-jnp
implementations so the 512-host-device AOT compile never lowers Mosaic ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.depthwise_conv import depthwise_conv as _dw
from repro.kernels.flash_attention import flash_attention_mha
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def rmsnorm(x, scale, eps: float = 1e-5):
    return _rmsnorm(x, scale, eps=eps, interpret=_interpret())


def depthwise_conv(x, w):
    return _dw(x, w, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Sk,K,hd) with K dividing H (GQA broadcast)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_mha(qt, kt, vt, causal=causal, q_offset=q_offset,
                              interpret=_interpret())
    return out.transpose(0, 2, 1, 3)
