"""Jit'd dispatch wrappers for the Pallas kernels.

Backend selection is automatic (kernels/backend.py): on a real TPU the
kernels lower via Mosaic; everywhere else they run in interpret mode. The
model code calls these through ``impl="pallas"`` switches; the default
dry-run path uses the pure-jnp implementations so the 512-host-device AOT
compile never lowers Mosaic ops.

All wrappers here are differentiable: flash_attention and rmsnorm carry
``jax.custom_vjp`` backward kernels, so ``jax.grad`` through a pallas model
never materializes an (Sq, Sk) tensor or an unfused norm backward.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (oracles re-exported for callers)
from repro.kernels.backend import default_interpret as _interpret  # noqa: F401
from repro.kernels.depthwise_conv import depthwise_conv as _dw
from repro.kernels.flash_attention import (flash_attention_mha, flash_decode,
                                           flash_decode_paged,
                                           flash_decode_spec,
                                           flash_decode_spec_paged)
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def rmsnorm(x, scale, eps: float = 1e-5):
    return _rmsnorm(x, scale, eps=eps)


def depthwise_conv(x, w):
    return _dw(x, w)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Sk,K,hd) with K dividing H (GQA broadcast).

    The head broadcast is a reshape of a broadcast_to — its transpose is a
    sum over the query-head group axis, which is exactly how dk/dv for a
    shared KV head accumulate over the G query heads that attended through
    it. The MHA kernel itself never sees GQA.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    if K != H:
        G = H // K
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (B, Sk, K, G, hd)).reshape(B, Sk, H, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (B, Sk, K, G, hd)).reshape(B, Sk, H, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_mha(qt, kt, vt, causal=causal, q_offset=q_offset)
    return out.transpose(0, 2, 1, 3)


def decode_attention(q, k, v, lengths, *, block_k: int = 256):
    """Single-token GQA decode against a ragged KV cache, fused.

    q: (B, 1, H, hd) — the new token's queries (cache already updated).
    k,v: (B, Smax, K, hd) cache buffers; lengths: (B,) or scalar valid counts.

    Unlike the prefill wrapper above, the KV heads are NOT broadcast to H —
    the kernel's query block holds the whole (G = H//K) query group, so each
    cache tile is streamed once per KV head. That is the decode win: the
    bytes moved per token drop from H/K x cache to 1 x cache.
    """
    B, _, H, hd = q.shape
    K = k.shape[2]
    qg = q.reshape(B, K, H // K, hd)  # (B,1,H,hd) -> grouped, same head order
    out = flash_decode(qg, k, v, lengths, block_k=block_k)
    return out.reshape(B, 1, H, v.shape[-1])


def decode_attention_mla(q_lat, q_rope, latent, k_rope, lengths, *,
                         scale: float, block_k: int = 256):
    """Absorbed-matrix MLA decode in the latent space, fused.

    q_lat: (B, 1, H, r) query absorbed through W_UK; q_rope: (B, 1, H, rd).
    latent: (B, Smax, r) cache; k_rope: (B, Smax, rd) cache.

    Keys are the concatenation [latent | k_rope] and values are the latent
    itself, so the same single-query kernel runs with K=1, G=H and an
    explicit softmax scale (1/sqrt(nope+rope), not 1/sqrt(r+rope)). Returns
    the latent-space context (B, 1, H, r); the caller applies W_UV.
    """
    q = jnp.concatenate([q_lat, q_rope], -1)  # (B, K=1, G=H, r+rd)
    kv = jnp.concatenate([latent, k_rope.astype(latent.dtype)], -1)[:, :, None]
    val = latent[:, :, None]
    return flash_decode(q, kv, val, lengths, scale=scale, block_k=block_k)


def decode_attention_paged(q, k_pool, v_pool, block_table, lengths):
    """Single-token GQA decode against a paged (block-pooled) KV cache.

    q: (B, 1, H, hd); k_pool/v_pool: (num_blocks, block_size, K, hd[v])
    shared physical blocks; block_table: (B, T) int32; lengths: (B,) or
    scalar valid counts. Same grouped-query streaming as
    ``decode_attention``, with the KV index maps going through the
    scalar-prefetched block table.
    """
    B, _, H, hd = q.shape
    K = k_pool.shape[2]
    qg = q.reshape(B, K, H // K, hd)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    out = flash_decode_paged(qg, k_pool, v_pool, block_table, lengths)
    return out.reshape(B, 1, H, v_pool.shape[-1])


def decode_attention_spec(q, k, v, lengths, *, block_k: int = 256):
    """Speculative multi-token GQA verify against a ragged KV cache, fused.

    q: (B, S, H, hd) — the S draft positions' queries (draft KVs already
    scattered at positions lengths[b]..lengths[b]+S-1). k,v: (B, Smax, K,
    hd[v]) cache buffers; lengths: (B,) or scalar BASE valid counts (before
    the drafts). Draft position qi attends cache positions
    < lengths[b] + qi + 1 — causal inside the verify tile. One kernel call
    verifies all S positions; the cache bytes are still streamed once per KV
    head, amortized over S tokens instead of one.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    # (B,S,H,hd) -> (B,K,S,G,hd): group the G heads sharing each KV head,
    # keeping draft order explicit so the kernel maps row r -> qi = r // G
    qg = q.reshape(B, S, K, H // K, hd).transpose(0, 2, 1, 3, 4)
    out = flash_decode_spec(qg, k, v, lengths, block_k=block_k)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, S, H, v.shape[-1])


def decode_attention_spec_paged(q, k_pool, v_pool, block_table, lengths):
    """Speculative multi-token GQA verify against a paged KV cache.

    q: (B, S, H, hd); pools/table/lengths as in ``decode_attention_paged``,
    with ``lengths`` the BASE valid counts and the block table covering the
    draft positions (boundary blocks appended before the verify call).
    """
    B, S, H, hd = q.shape
    K = k_pool.shape[2]
    qg = q.reshape(B, S, K, H // K, hd).transpose(0, 2, 1, 3, 4)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    out = flash_decode_spec_paged(qg, k_pool, v_pool, block_table, lengths)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, S, H, v_pool.shape[-1])


def decode_attention_mla_paged(q_lat, q_rope, latent_pool, k_rope_pool,
                               block_table, lengths, *, scale: float):
    """Absorbed-matrix MLA decode over paged latent pools.

    latent_pool: (num_blocks, block_size, r); k_rope_pool: (..., rd).
    Keys are [latent | k_rope] per block, values the latent itself — the
    paged kernel runs with K=1, G=H exactly like the contiguous MLA path.
    """
    B = q_lat.shape[0]
    q = jnp.concatenate([q_lat, q_rope], -1)  # (B, K=1, G=H, r+rd)
    kv = jnp.concatenate(
        [latent_pool, k_rope_pool.astype(latent_pool.dtype)], -1)[:, :, None]
    val = latent_pool[:, :, None]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    return flash_decode_paged(q, kv, val, block_table, lengths, scale=scale)
