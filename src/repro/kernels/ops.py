"""Jit'd dispatch wrappers for the Pallas kernels.

Backend selection is automatic (kernels/backend.py): on a real TPU the
kernels lower via Mosaic; everywhere else they run in interpret mode. The
model code calls these through ``impl="pallas"`` switches; the default
dry-run path uses the pure-jnp implementations so the 512-host-device AOT
compile never lowers Mosaic ops.

All wrappers here are differentiable: flash_attention and rmsnorm carry
``jax.custom_vjp`` backward kernels, so ``jax.grad`` through a pallas model
never materializes an (Sq, Sk) tensor or an unfused norm backward.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (oracles re-exported for callers)
from repro.kernels.backend import default_interpret as _interpret  # noqa: F401
from repro.kernels.depthwise_conv import depthwise_conv as _dw
from repro.kernels.flash_attention import flash_attention_mha
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def rmsnorm(x, scale, eps: float = 1e-5):
    return _rmsnorm(x, scale, eps=eps)


def depthwise_conv(x, w):
    return _dw(x, w)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Sk,K,hd) with K dividing H (GQA broadcast).

    The head broadcast is a reshape of a broadcast_to — its transpose is a
    sum over the query-head group axis, which is exactly how dk/dv for a
    shared KV head accumulate over the G query heads that attended through
    it. The MHA kernel itself never sees GQA.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    if K != H:
        G = H // K
        k = jnp.broadcast_to(k[:, :, :, None, :],
                             (B, Sk, K, G, hd)).reshape(B, Sk, H, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :],
                             (B, Sk, K, G, hd)).reshape(B, Sk, H, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_mha(qt, kt, vt, causal=causal, q_offset=q_offset)
    return out.transpose(0, 2, 1, 3)
