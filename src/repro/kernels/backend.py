"""Backend autodetection shared by every Pallas kernel wrapper (via ops.py).

Mosaic only lowers on a real TPU backend; everywhere else (the CPU CI
container, GPU hosts) the kernels run in Pallas interpret mode. Kernel entry
points take ``interpret=None`` and resolve it here so no call site hardcodes
a backend assumption.
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def default_interpret() -> bool:
    """True when the current backend cannot lower Mosaic (i.e. not a TPU)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def attn_bf16(lowp: Optional[bool] = None) -> bool:
    """bf16 score/probability accumulation toggle for the flash kernels.

    Mirrors the chunked path's ``REPRO_ATTN_BF16``: dot-product *inputs* drop
    to bf16 (halving the dominant VMEM/HBM traffic) while the online-softmax
    statistics and the output accumulator stay f32. Resolved eagerly in the
    non-jitted wrappers so flipping the env var between calls takes effect.
    """
    if lowp is not None:
        return bool(lowp)
    return os.environ.get("REPRO_ATTN_BF16", "0") == "1"


def auto_attn_impl(seq_len: int, *, interpret: Optional[bool] = None) -> str:
    """Attention-kernel policy for ``--attn-impl auto``.

    Policy table (seq length x backend capability):
      - short sequences: ``naive`` — exact, no tiling overhead, and the O(S^2)
        score matrix is small enough to materialize;
      - long sequences on a backend that can lower Mosaic (real TPU,
        ``interpret`` False): ``pallas`` — the training-fit flash kernel with
        the custom_vjp backward;
      - long sequences everywhere else (CPU/GPU, interpret mode): ``chunked``
        — the jnp online-softmax fallback; interpreted Pallas would be
        orders of magnitude slower than the same math in jnp.
    """
    if seq_len <= 512:
        return "naive"
    return "chunked" if resolve_interpret(interpret) else "pallas"


def auto_decode_impl(cache_len: int, *, interpret: Optional[bool] = None) -> str:
    """Decode-attention policy for ``--attn-impl auto`` in the serve path.

    Decode latency is KV-bandwidth-bound, so the crossover is governed by how
    much cache a step streams, not by compute:
      - short caches: ``naive`` — a single (H, cache_len) score row is cheap
        and exact, and kernel launch/tiling overhead would dominate;
      - long caches on a backend that can lower Mosaic: ``pallas`` — the
        single-query flash-decode kernel streams only the ``cache_len``-valid
        KV tiles and shares each KV head across its GQA query group;
      - long caches in interpret mode (CPU/GPU CI): ``naive`` — interpreted
        Pallas is orders of magnitude slower than the same math in jnp.
    """
    if cache_len < 512:
        return "naive"
    return "naive" if resolve_interpret(interpret) else "pallas"


def divisor_block(size: int, preferred: int) -> int:
    """Largest block <= preferred that divides size (handles ragged dims)."""
    b = min(preferred, size)
    while size % b:
        b -= 1
    return b


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new JAX) / ``pltpu.TPUCompilerParams`` (0.4.x).

    The class was renamed between releases; this must track repro.compat's
    version span or the real-TPU (interpret=False) path dies on import of
    whichever name the install lacks.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
